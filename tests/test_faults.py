"""Fault-injection fabric (`repro.sim.fault`): primitive semantics, seeded
determinism, delivery-vs-offline tie-breaking, and the two protocol
hardenings injection surfaced (duplicate-model dedup in aggregation,
trainer-side aggregator failover after death-post-sample)."""

import dataclasses

import pytest

from repro.config import ModestConfig, TrainConfig
from repro.core import messages as M
from repro.core.node import ModestNode
from repro.core.tasks import AbstractTask
from repro.sim.clock import Simulator
from repro.sim.fault import (AggregatorKill, Drop, Duplicate, FaultInjector,
                             FaultSchedule, Jitter, LatencySpike, Partition,
                             Straggler)
from repro.sim.network import Network
from repro.sim.runner import DSGDSession, GossipSession, ModestSession

MCFG = ModestConfig(n_nodes=20, sample_size=4, n_aggregators=2,
                    success_fraction=1.0, ping_timeout=1.0,
                    activity_window=20)
TASK = AbstractTask(model_bytes_=100_000)


class _Sink:
    """Minimal registered endpoint: records every delivered message."""

    def __init__(self, node_id):
        self.node_id = node_id
        self.online = True
        self.got = []

    def receive(self, msg):
        self.got.append(msg)


class _Harness:
    """Bare sim + network + injector (no protocol), for primitive tests."""

    def __init__(self, rules, *, n=4, seed=0, contention=True):
        self.sim = Simulator()
        self.net = Network(self.sim, n, bandwidth=1e6, contention=contention)
        self.nodes = {}
        for i in range(n):
            sink = _Sink(str(i))
            self.net.register(sink)
            self.nodes[str(i)] = sink
        self.injector = FaultInjector(FaultSchedule(rules=rules, seed=seed),
                                      self)
        self.injector.install(1e9)

    # FaultInjector kill/rejoin hooks (unused by primitive tests)
    def _trace_offline(self, nid):
        self.nodes[nid].online = False

    def _trace_online(self, nid):
        self.nodes[nid].online = True


def _ping(src="0"):
    return M.Ping(sender=src, round_k=1)


# ------------------------------------------------------------- primitives


def test_drop_loses_messages_but_charges_sender():
    h = _Harness([Drop(p=1.0)])
    for _ in range(5):
        h.net.send("0", "1", _ping())
    h.sim.run(until=10.0)
    assert h.nodes["1"].got == []
    assert h.net.bytes_out["0"] == 5 * M.HEADER_BYTES   # lost in transit
    assert h.net.bytes_in["1"] == 0
    assert h.injector.stats["dropped"] == 5


def test_drop_selectors_scope_loss_to_link_kind_window():
    h = _Harness([Drop(p=1.0, src=("0",), dst=("1",), kinds=("Ping",),
                       t0=0.0, t1=5.0)])
    h.net.send("0", "1", _ping())              # matches: dropped
    h.net.send("0", "1", M.Pong(sender="0", round_k=1))   # kind mismatch
    h.net.send("2", "1", _ping("2"))           # src mismatch
    h.sim.schedule(6.0, lambda: h.net.send("0", "1", _ping()))  # after t1
    h.sim.run(until=10.0)
    kinds = [type(m).__name__ for m in h.nodes["1"].got]
    assert sorted(kinds) == ["Ping", "Ping", "Pong"]


def test_duplicate_delivers_twice_and_charges_twice():
    h = _Harness([Duplicate(p=1.0, gap=0.5)])
    h.net.send("0", "1", _ping())
    h.sim.run(until=10.0)
    assert len(h.nodes["1"].got) == 2
    # spurious retransmission is real traffic: sender pays for both copies
    assert h.net.bytes_out["0"] == 2 * M.HEADER_BYTES
    assert h.net.bytes_in["1"] == 2 * M.HEADER_BYTES
    assert h.net.msgs_by_type["Ping"] == 2


def test_jitter_bounds_reordering():
    """Jittered arrivals stay within max_delay of the clean analytic
    delivery time, so reordering is bounded: a message can be overtaken
    only by messages sent less than max_delay earlier."""
    for seed in range(5):
        h = _Harness([Jitter(max_delay=0.3)], seed=seed)
        lat = (h.net.latency("0", "1")
               + h.net.transfer_time("0", "1", M.HEADER_BYTES))
        arrivals = []
        orig = h.nodes["1"].receive
        h.nodes["1"].receive = lambda m: (arrivals.append(h.sim.now),
                                          orig(m))
        h.net.send("0", "1", _ping())
        h.sim.run(until=10.0)
        assert len(arrivals) == 1
        assert lat - 1e-12 <= arrivals[0] <= lat + 0.3 + 1e-12


def test_latency_spike_window():
    h = _Harness([LatencySpike(extra=2.0, t0=0.0, t1=1.0)])
    times = []
    orig = h.nodes["1"].receive
    h.nodes["1"].receive = lambda m: (times.append(h.sim.now), orig(m))
    lat = (h.net.latency("0", "1")
           + h.net.transfer_time("0", "1", M.HEADER_BYTES))
    h.net.send("0", "1", _ping())                       # inside window
    h.sim.schedule(5.0, lambda: h.net.send("0", "1", _ping()))  # outside
    h.sim.run(until=20.0)
    assert times == pytest.approx([lat + 2.0, 5.0 + lat])


def test_partition_cuts_cross_group_traffic_then_heals():
    h = _Harness([Partition(groups=(("0", "1"),), t0=0.0, t1=5.0)])
    h.net.send("0", "2", _ping())          # crosses the cut: dropped
    h.net.send("0", "1", _ping())          # same group: delivered
    h.sim.schedule(6.0, lambda: h.net.send("0", "2", _ping()))  # healed
    h.sim.run(until=10.0)
    assert len(h.nodes["1"].got) == 1
    assert len(h.nodes["2"].got) == 1      # only the post-heal copy
    assert h.injector.stats["partitioned"] == 1


def test_partition_aborts_inflight_flows_crossing_cut():
    """A model transfer mid-flight when the cut lands must die with it
    (abort_flows), not sail through the partition window."""
    h = _Harness([Partition(groups=(("0",),), t0=1.0, t1=50.0)])
    big = M.TrainMsg(sender="0", round_k=1,
                     model=M.ModelPayload(nbytes=2_000_000), view=None)
    h.net.send("0", "1", big)              # ~2 s transfer at 1 MB/s
    h.sim.run(until=60.0)
    assert h.nodes["1"].got == []
    assert h.net.flows_aborted >= 1
    assert h.injector.stats["flows_severed"] >= 1


def test_partition_blocks_flow_starting_inside_window():
    """A payload sent just before t0 whose flow would *start* inside the
    window (propagation delay) must not sneak through the cut."""
    h = _Harness([Partition(groups=(("0",),), t0=0.001, t1=50.0)])
    lat = h.net.latency("0", "1")
    assert lat > 0.001                     # flow starts after the cut
    big = M.TrainMsg(sender="0", round_k=1,
                     model=M.ModelPayload(nbytes=2_000_000), view=None)
    h.net.send("0", "1", big)
    h.sim.run(until=60.0)
    assert h.nodes["1"].got == []
    assert h.net.flows_aborted >= 1


def test_straggler_slows_then_restores_speed():
    sched = FaultSchedule(rules=(Straggler(nodes=("3",), factor=4.0,
                                           t0=10.0, t1=20.0),), seed=0)
    s = ModestSession(n_nodes=8, mcfg=MCFG, task=TASK, seed=0, fault=sched)
    base = s.nodes["3"].train_speed
    s.sim.run(until=5.0)
    assert s.nodes["3"].train_speed == base
    s.fault_injector.install(100.0)
    s.sim.run(until=15.0)
    assert s.nodes["3"].train_speed == pytest.approx(4.0 * base)
    s.sim.run(until=25.0)
    assert s.nodes["3"].train_speed == base      # restored exactly


def test_schedule_is_seed_deterministic():
    def stats(seed):
        h = _Harness([Drop(p=0.3), Duplicate(p=0.3), Jitter(max_delay=0.1)],
                     seed=seed)
        for i in range(200):
            h.net.send(str(i % 3), "3", _ping(str(i % 3)))
        h.sim.run(until=50.0)
        return dict(h.injector.stats), len(h.nodes["3"].got)

    assert stats(7) == stats(7)
    assert stats(7) != stats(8)        # and the seed actually matters


def test_zero_cost_when_no_fault_attached():
    """fault=None leaves the network object on the pre-fault path (the
    byte-identical golden proof lives in test_determinism.py)."""
    s = ModestSession(n_nodes=6, mcfg=MCFG, task=TASK, seed=0)
    assert s.fault_injector is None and s.net.fault is None
    res = s.run(30.0)
    assert res.fault_stats == {} and res.rounds_completed > 0


# ------------------------------------------- delivery-vs-offline tie-break


@pytest.mark.parametrize("session_cls",
                         [ModestSession, DSGDSession, GossipSession])
def test_offline_beats_delivery_on_shared_timestamp(session_cls):
    """When a message arrival and a churn-offline event share a timestamp,
    the offline transition wins and the message is dropped. This is pinned
    by schedule order: AvailabilityDriver (and the fault injector) install
    their events before any protocol traffic is scheduled, and the event
    queue breaks time ties by insertion sequence."""
    kw = dict(n_nodes=6, task=TASK, seed=0)
    if session_cls is ModestSession:
        kw["mcfg"] = MCFG
    s = session_cls(**kw)
    msg = _ping()
    t_deliver = (s.net.latency("0", "1")
                 + s.net.transfer_time("0", "1", msg.size_bytes()))
    received = []
    node = s.nodes["1"]
    orig = node.receive
    node.receive = lambda m: (received.append(m), orig(m))

    # churn scheduled BEFORE the send: same timestamp -> offline wins
    s.sim.schedule(t_deliver, lambda: s._trace_offline("1"))
    s.net.send("0", "1", msg)
    s.sim.run(until=t_deliver)
    assert msg not in received

    # mirror: delivery scheduled before the (equal-time) churn -> delivered
    s._trace_online("1")
    msg2 = _ping()
    t2 = s.sim.now + t_deliver
    s.net.send("0", "1", msg2)
    s.sim.schedule(t_deliver, lambda: s._trace_offline("1"))
    s.sim.run(until=t2)
    assert msg2 in received


# --------------------------------------------------- protocol regressions


def _bare_node(mcfg):
    sim = Simulator()
    net = Network(sim, 4)
    node = ModestNode("0", sim, net, mcfg, TrainConfig(),
                      AbstractTask(model_bytes_=1000))
    node.bootstrap(["0", "1", "2", "3"])
    return sim, node


def test_duplicate_model_not_aggregated_twice():
    """Regression (duplicate-delivery fault): two copies of the same
    sender's model for one round must count once toward sf·s, or the
    average silently double-weights the duplicated node."""
    mcfg = ModestConfig(n_nodes=4, sample_size=2, success_fraction=1.0,
                        ping_timeout=1.0)
    _, node = _bare_node(mcfg)
    msg = M.AggregateMsg(sender="1", round_k=5,
                         model=M.ModelPayload(nbytes=1000), view=None)
    node.receive(msg)
    node.receive(msg)                       # duplicated in transit
    assert 5 not in node._agg_models_done   # threshold (2) NOT met by dup
    assert node.dup_models_dropped == 1
    node.receive(M.AggregateMsg(sender="2", round_k=5,
                                model=M.ModelPayload(nbytes=1000), view=None))
    assert 5 in node._agg_models_done       # distinct senders aggregate
    assert node.agg_log == [(5, ("1", "2"))]


def test_agg_log_senders_unique_under_duplication_storm():
    sched = FaultSchedule(rules=(Duplicate(p=0.5, gap=0.3),), seed=3)
    s = ModestSession(n_nodes=12, mcfg=MCFG, task=TASK, seed=1, fault=sched)
    res = s.run(120.0)
    assert res.rounds_completed > 5
    assert res.fault_stats["duplicated"] > 50
    dropped = sum(n.dup_models_dropped for n in s.nodes.values())
    assert dropped > 0                       # the guard actually fired
    for node in s.nodes.values():
        for k, senders in node.agg_log:
            assert len(senders) == len(set(senders)), (node.node_id, k)


def test_aggregator_death_post_sample_wedges_without_failover():
    """Regression (paper §4 failover): with one trainer and one
    aggregator per round, killing the designated aggregator the moment
    the trained model goes on the wire wedges the legacy protocol
    permanently; trainer-side failover re-samples A^{k+1} (excluding the
    dead node) and keeps the session live."""
    base = ModestConfig(n_nodes=20, sample_size=1, n_aggregators=1,
                        success_fraction=1.0, ping_timeout=1.0,
                        activity_window=30)
    kill = FaultSchedule(rules=(AggregatorKill(round_k=4,
                                               rejoin_after=None),), seed=0)

    def run(failover):
        mcfg = dataclasses.replace(base, failover=failover)
        s = ModestSession(n_nodes=20, mcfg=mcfg, task=TASK, seed=0,
                          fault=kill)
        res = s.run(300.0)
        return res, sum(n.failovers for n in s.nodes.values())

    wedged, fov0 = run(failover=False)
    assert wedged.fault_stats["aggregator_kills"] == 1
    assert wedged.rounds_completed <= 4 and fov0 == 0     # the old bug

    live, fov1 = run(failover="auto")     # auto = on, fault fabric attached
    assert live.rounds_completed > wedged.rounds_completed + 20
    assert fov1 > 0


def test_failover_rejoined_aggregator_rejoins_cleanly():
    """Kill + Alg.-2 rejoin: the killed aggregator comes back, is
    re-registered, and the session keeps progressing."""
    sched = FaultSchedule(rules=(AggregatorKill(round_k=3, rejoin_after=10.0),),
                          seed=0)
    s = ModestSession(n_nodes=16, mcfg=MCFG, task=TASK, seed=0, fault=sched)
    res = s.run(180.0)
    assert res.fault_stats["aggregator_kills"] == 1
    assert all(n.online for n in s.nodes.values())
    assert res.rounds_completed > 30


def test_ack_cancels_failover_watch_permanently():
    """Regression pin (audit: AggregatorKill + Duplicate interplay): a
    healthy Ack must cancel the trainer's failover watch for that round
    *permanently* — duplicated Acks, later timer firings, even the acked
    aggregator dying afterwards must not re-trigger a re-send."""
    mcfg = ModestConfig(n_nodes=4, sample_size=1, n_aggregators=1,
                        ping_timeout=1.0, failover=True)
    sim, node = _bare_node(mcfg)
    node._push_model(3, M.ModelPayload(nbytes=1000), ["1"])  # arms watch
    node.receive(M.Ack(sender="1", round_k=4))
    node.receive(M.Ack(sender="1", round_k=4))     # Duplicate on the ack link
    horizon = (node.FAILOVER_TIMEOUT_MULT * node.timeout
               * (node.FAILOVER_MAX_RETRIES + 2))
    sim.run(until=horizon)
    assert node.failovers == 0

    # negative control: without the Ack the same watch does fire
    sim2, node2 = _bare_node(mcfg)
    node2._push_model(3, M.ModelPayload(nbytes=1000), ["1"])
    sim2.run(until=horizon)
    assert node2.failovers >= 1


def test_no_spurious_failover_under_kill_plus_duplicate():
    """Whole-session pin of the same audit: one aggregator killed at
    round 4 while half the traffic duplicates. The co-aggregator keeps
    acking/progressing, so every watch is cancelled — zero failovers,
    full progress, duplicate guard absorbing the retransmit storm."""
    sched = FaultSchedule(rules=(AggregatorKill(round_k=4, rejoin_after=10.0),
                                 Duplicate(p=0.5, gap=0.4)), seed=0)
    s = ModestSession(n_nodes=20, mcfg=MCFG, task=TASK, seed=0, fault=sched)
    res = s.run(200.0)
    assert res.fault_stats["aggregator_kills"] == 1
    assert res.rounds_completed > 100
    assert sum(n.dup_models_dropped for n in s.nodes.values()) > 50
    assert sum(n.failovers for n in s.nodes.values()) == 0


def test_partition_mid_transfer_charges_partial_bytes():
    """abort_flows partial-byte accounting: a Partition cutting a flow
    mid-transfer charges the receiver exactly the bytes streamed up to
    the cut — more than zero, less than the payload, never minting."""
    big = 1_000_000                     # ~1s at the 1e6 B/s harness rate
    lat = None

    class _Model(M.Message):
        def size_bytes(self):
            return big

    h = _Harness([])                    # clean fabric; cut applied manually
    lat = h.net.latency("0", "1")
    h.net.send("0", "1", _Model(sender="0"))
    cut_at = lat + 0.4                  # flow is mid-stream
    h.sim.schedule(cut_at, lambda: h.net.abort_flows(
        lambda src, dst: src == "0" and dst == "1"))
    h.sim.run(until=10.0)
    assert h.nodes["1"].got == []       # never delivered
    got = h.net.bytes_in["1"]
    assert 0 < got < big
    assert got <= h.net.bytes_out["0"]  # conservation
    assert h.net.flows_aborted == 1

    # same cut through the injector's Partition path
    h2 = _Harness([Partition(groups=(("0",),), t0=0.5, t1=5.0)])
    h2.net.send("0", "1", _Model(sender="0"))
    h2.sim.run(until=10.0)
    assert h2.nodes["1"].got == []
    assert 0 < h2.net.bytes_in["1"] < big


def test_rounds_progress_under_bounded_loss():
    """Liveness: 20% loss + jitter + occasional retransmits still lets
    MoDeST complete rounds *throughout* the horizon (sampler retries +
    stall aggregation + failover compose — no wedge, though each lost
    ping costs a Δt timeout so rounds are legitimately slower; the
    conformance suite randomizes this further)."""
    mcfg = dataclasses.replace(MCFG, sample_size=5, success_fraction=0.6)
    sched = FaultSchedule(rules=(Drop(p=0.2), Jitter(max_delay=0.2),
                                 Duplicate(p=0.1)), seed=4)
    res = ModestSession(n_nodes=16, mcfg=mcfg, task=TASK, seed=0,
                        fault=sched).run(150.0)
    assert res.rounds_completed >= 15
    # sustained progress, not a fast start that wedges: rounds complete
    # in the last third of the horizon too
    assert any(t > 100.0 for t, _ in res.round_times)


def test_two_run_determinism_with_faults():
    import hashlib
    import json

    sched = FaultSchedule(rules=(Drop(p=0.15), Duplicate(p=0.1),
                                 Jitter(max_delay=0.25),
                                 Straggler(nodes=3, factor=5.0, t0=20,
                                           t1=60)), seed=9)

    def fingerprint():
        s = ModestSession(n_nodes=14, mcfg=MCFG, task=TASK, seed=2,
                          fault=sched)
        res = s.run(120.0)
        blob = json.dumps({"rt": res.round_times, "usage": res.usage,
                           "fault": res.fault_stats}, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()

    assert fingerprint() == fingerprint()


def test_dsgd_duplicate_model_not_averaged_twice():
    """Regression: the D-SGD ring has exactly one in-neighbor per round;
    a duplicated delivery must not double-weight that neighbor in the
    synchronous average."""
    sched = FaultSchedule(rules=(Duplicate(p=0.6, gap=0.2),), seed=2)
    s = DSGDSession(n_nodes=8, task=TASK, seed=0, fault=sched)
    res = s.run(60.0)
    assert res.fault_stats["duplicated"] > 10
    dropped = sum(n.dup_models_dropped for n in s.nodes.values())
    assert dropped > 0
    for node in s.nodes.values():
        for k, senders in node.agg_log:
            assert len(senders) == len(set(senders)), (node.node_id, k)


def test_straggler_windows_nest_and_restore_exactly():
    """Overlapping straggler windows compose multiplicatively and the
    last one to end restores the exact original speed."""
    sched = FaultSchedule(rules=(
        Straggler(nodes=("3",), factor=4.0, t0=10.0, t1=30.0),
        Straggler(nodes=("3",), factor=2.0, t0=20.0, t1=50.0)), seed=0)
    s = ModestSession(n_nodes=8, mcfg=MCFG, task=TASK, seed=0, fault=sched)
    base = s.nodes["3"].train_speed
    s.fault_injector.install(100.0)
    s.sim.run(until=15.0)
    assert s.nodes["3"].train_speed == pytest.approx(4.0 * base)
    s.sim.run(until=25.0)
    assert s.nodes["3"].train_speed == pytest.approx(8.0 * base)
    s.sim.run(until=35.0)          # first window ended, second still live
    assert s.nodes["3"].train_speed == pytest.approx(2.0 * base)
    s.sim.run(until=55.0)
    assert s.nodes["3"].train_speed == base      # exact, not approx


def test_fedavg_failover_stays_centralized():
    """Regression: failover must not fire in FL-emulation mode — a
    decentralized re-sample would spawn rogue aggregators inside the
    centralized baseline. The churn-exempt server needs no failover."""
    from repro.sim.runner import fedavg_session

    sched = FaultSchedule(rules=(Drop(p=0.05),), seed=1)
    mcfg = ModestConfig(n_nodes=16, sample_size=4, ping_timeout=1.0,
                        activity_window=20)
    s = fedavg_session(n_nodes=16, mcfg=mcfg, task=TASK, seed=0, fault=sched)
    res = s.run(200.0)
    assert res.rounds_completed > 10
    assert sum(n.failovers for n in s.nodes.values()) == 0
    server = s._fixed_id
    rogue = [n.node_id for n in s.nodes.values()
             if n.node_id != server and n.agg_log]
    assert rogue == [], f"non-server nodes aggregated: {rogue}"


def test_acks_suppress_false_positive_failovers():
    """A successful push is acked by the aggregator, cancelling the
    trainer's failover watch: light loss must not trigger a storm of
    spurious model re-sends (it did before receipt acks)."""
    sched = FaultSchedule(rules=(Drop(p=0.02),), seed=6)
    s = ModestSession(n_nodes=16, mcfg=MCFG, task=TASK, seed=0, fault=sched)
    res = s.run(200.0)
    assert res.rounds_completed > 50
    failovers = sum(n.failovers for n in s.nodes.values())
    assert failovers <= res.rounds_completed / 10, (
        f"{failovers} failovers over {res.rounds_completed} rounds — "
        "acks are not suppressing false positives")
