"""Stateful conformance for secure aggregation under the fault fabric.

Same shape as tests/test_conformance.py — a hypothesis state machine
drives random fault windows (loss, duplication, partitions, stragglers,
targeted aggregator kills, node kill/heal) against a live ModestSession
with ``secure_agg="masked"`` — plus the two secure-agg invariants from
docs/SECUREAGG.md, checked after every step:

* **no plaintext model ever leaves a trainer** — every model push on the
  wire is a ``MaskedModelMsg`` whose params is a ``SealedModel``; a bare
  ``AggregateMsg`` is a leak (a send-time sniffer records violations);
* **unmask only at or above threshold** — every ``secagg_log`` entry's
  share margin is >= 0: no aggregation of sealed rows ever happened with
  fewer than t surviving shares for any arrived sender.

The plain invariants (monotone rounds, byte conservation — which now
includes partial bytes of partition-cut flows —, duplicate-free
aggregation, monotone fault counters) are rechecked here too: the
secure path must not regress them. Whole-run liveness and two-run
determinism properties close the file.
"""

import hashlib
import json

from hypothesis import given, settings, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                 invariant, rule)

from repro.config import ModestConfig
from repro.core.tasks import AbstractTask
from repro.secureagg import SealedModel
from repro.sim.fault import (AggregatorKill, Drop, Duplicate, FaultSchedule,
                             Jitter, Partition, Straggler)
from repro.sim.runner import ModestSession

N = 16
MCFG = ModestConfig(n_nodes=N, sample_size=4, n_aggregators=2,
                    success_fraction=0.75, ping_timeout=1.0,
                    activity_window=20, secure_agg="masked")


def _session(seed, fault):
    return ModestSession(n_nodes=N, mcfg=MCFG,
                         task=AbstractTask(model_bytes_=100_000),
                         seed=seed, fault=fault)


def _arm_sniffer(session):
    """Send-time wire tap: records every plaintext model payload."""
    leaks = []
    orig = session.net.send

    def send(src, dst, msg):
        name = type(msg).__name__
        model = getattr(msg, "model", None)
        if model is not None and name == "AggregateMsg":
            leaks.append((src, dst, name, "bare AggregateMsg"))
        if name == "MaskedModelMsg" and not isinstance(model.params,
                                                       SealedModel):
            leaks.append((src, dst, name, "unsealed params"))
        orig(src, dst, msg)

    session.net.send = send
    return leaks


class SecureAggConformance(RuleBasedStateMachine):
    @initialize(seed=st.integers(0, 2**16))
    def setup(self, seed):
        self.session = _session(seed, FaultSchedule(rules=(), seed=seed))
        self.leaks = _arm_sniffer(self.session)
        self.injector = self.session.fault_injector
        self.injector.install(10_000.0)
        self.t = 0.0
        self._last_stats = {}

    # ------------------------------------------------------------- rules

    @rule(dt=st.floats(1.0, 15.0))
    def advance(self, dt):
        self.t += dt
        self.session.sim.run(until=self.t)

    @rule(p=st.floats(0.05, 0.35), dur=st.floats(2.0, 12.0))
    def loss_window(self, p, dur):
        self.injector.add(Drop(p=p, t0=self.t, t1=self.t + dur))

    @rule(p=st.floats(0.05, 0.4), gap=st.floats(0.01, 0.5),
          dur=st.floats(2.0, 12.0))
    def duplicate_window(self, p, gap, dur):
        self.injector.add(Duplicate(p=p, gap=gap, t0=self.t,
                                    t1=self.t + dur))

    @rule(d=st.floats(0.02, 0.5), dur=st.floats(2.0, 12.0))
    def jitter_window(self, d, dur):
        self.injector.add(Jitter(max_delay=d, t0=self.t, t1=self.t + dur))

    @rule(cut=st.integers(1, N - 1), dur=st.floats(2.0, 10.0))
    def partition_window(self, cut, dur):
        group = tuple(str(i) for i in range(cut))
        self.injector.add(Partition(groups=(group,), t0=self.t,
                                    t1=self.t + dur))

    @rule(k=st.integers(1, 3), factor=st.floats(2.0, 8.0),
          dur=st.floats(2.0, 15.0))
    def straggler_window(self, k, factor, dur):
        self.injector.add(Straggler(nodes=k, factor=factor, t0=self.t,
                                    t1=self.t + dur))

    @rule(ahead=st.integers(1, 4), downtime=st.floats(2.0, 10.0))
    def aggregator_kill(self, ahead, downtime):
        """Kill whoever receives models for an upcoming round — the
        targeted secure-agg stressor: the co-aggregator must finish the
        round or the threshold gate must hold it sealed."""
        rounds = self.session.result.rounds_completed
        self.injector.add(AggregatorKill(round_k=rounds + ahead,
                                         rejoin_after=downtime))

    @rule(victim=st.integers(0, N - 1), downtime=st.floats(1.0, 12.0))
    def kill_and_heal(self, victim, downtime):
        nid = str(victim)
        self.session._trace_offline(nid)
        self.session.sim.schedule(downtime,
                                  lambda: self.session._trace_online(nid))

    # -------------------------------------------------------- invariants

    @invariant()
    def no_plaintext_on_wire(self):
        assert self.leaks == [], self.leaks[:5]

    @invariant()
    def unmask_only_at_threshold(self):
        for node in self.session.nodes.values():
            for k, t, n_sealed, margin in node.secagg_log:
                assert margin >= 0, (
                    f"node {node.node_id} unmasked round {k} with a sender "
                    f"{-margin} shares below threshold {t}")
                assert n_sealed >= 1

    @invariant()
    def rounds_monotone(self):
        rt = self.session.result.round_times
        for (t0, k0), (t1, k1) in zip(rt, rt[1:]):
            assert t1 >= t0 and k1 > k0
        if rt:
            assert rt[-1][0] <= self.session.sim.now + 1e-9

    @invariant()
    def bytes_conserved(self):
        net = self.session.net
        sent = sum(net.bytes_out.values())
        received = sum(net.bytes_in.values())
        assert received <= sent, (
            f"minted bytes from nothing: received {received} > sent {sent}")

    @invariant()
    def no_model_aggregated_twice(self):
        for node in self.session.nodes.values():
            for k, senders in node.agg_log:
                assert len(senders) == len(set(senders)), (node.node_id, k)

    @invariant()
    def fault_stats_monotone(self):
        stats = dict(self.injector.stats)
        for key, v in stats.items():
            assert v >= self._last_stats.get(key, 0)
            assert v >= 0
        self._last_stats = stats


TestSecureAggConformance = SecureAggConformance.TestCase
TestSecureAggConformance.settings = settings(max_examples=20, deadline=None,
                                             stateful_step_count=10)


# ---------------------------------------------------------------------------
# Whole-run properties
# ---------------------------------------------------------------------------


def _random_schedule(seed: int) -> FaultSchedule:
    import random

    r = random.Random(seed)
    rules = [Drop(p=r.uniform(0.05, 0.2)),
             Jitter(max_delay=r.uniform(0.05, 0.4)),
             Duplicate(p=r.uniform(0.05, 0.3), gap=r.uniform(0.05, 0.3)),
             AggregatorKill(round_k=r.randint(3, 8),
                            rejoin_after=r.uniform(5, 15))]
    if r.random() < 0.5:
        t0 = r.uniform(20, 60)
        rules.append(Partition(groups=(tuple(str(i) for i in
                                             range(r.randint(2, 6))),),
                               t0=t0, t1=t0 + r.uniform(3, 10)))
    if r.random() < 0.5:
        t0 = r.uniform(10, 80)
        rules.append(Straggler(nodes=r.randint(1, 3),
                               factor=r.uniform(2, 6),
                               t0=t0, t1=t0 + r.uniform(5, 20)))
    return FaultSchedule(rules=tuple(rules), seed=seed)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_secure_completion_under_bounded_loss(seed):
    """Masked rounds keep completing through the whole horizon under any
    bounded-severity schedule: mask recovery composes with failover and
    stall-aggregation instead of wedging the session."""
    s = _session(seed % 7, _random_schedule(seed))
    leaks = _arm_sniffer(s)
    res = s.run(150.0)
    assert res.rounds_completed >= 5
    assert any(t > 100.0 for t, _ in res.round_times), (
        "no round completed in the final third — wedged?")
    assert leaks == []
    logs = [e for n in s.nodes.values() for e in n.secagg_log]
    assert logs                      # recovery actually ran, gate held
    assert all(margin >= 0 for _, _, _, margin in logs)


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_secure_two_run_determinism(seed):
    """(session seed, schedule) -> trajectory stays a pure function with
    masking, shares and recovery in the loop (DL001 replay contract)."""

    def fingerprint():
        s = _session(seed % 5, _random_schedule(seed))
        res = s.run(100.0)
        logs = sorted((n.node_id, e) for n in s.nodes.values()
                      for e in n.secagg_log)
        blob = json.dumps({"rt": res.round_times, "usage": res.usage,
                           "fault": res.fault_stats, "secagg": logs},
                          sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()

    assert fingerprint() == fingerprint()
