"""Alg. 1 properties: determinism, cross-node consistency, the
*mostly-consistent* guarantee under view divergence, and the live
``Sampler`` state machine (concurrent same-round samples)."""

import string

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import messages as M
from repro.core.hashing import sample_order, select_aggregators, select_sample
from repro.core.sampling import Sampler
from repro.sim.clock import Simulator
from repro.sim.network import Network

ids = st.lists(st.text(string.ascii_lowercase + string.digits, min_size=1,
                       max_size=12), min_size=1, max_size=60, unique=True)


@given(ids, st.integers(0, 10_000))
def test_order_deterministic(candidates, k):
    assert sample_order(candidates, k) == sample_order(list(candidates), k)


@given(ids, st.integers(0, 10_000))
def test_order_is_permutation(candidates, k):
    assert sorted(sample_order(candidates, k)) == sorted(candidates)


@given(ids, st.integers(0, 1000), st.integers(1, 20))
def test_consistent_views_consistent_samples(candidates, k, s):
    """Two nodes with identical views derive identical samples — the FL
    server's single sample, decentralized."""
    a = select_sample(candidates, k, s)
    b = select_sample(sorted(candidates), k, s)   # different iteration order
    assert a == b


@given(ids, st.integers(0, 1000))
def test_rounds_randomize_order(candidates, k):
    """Different rounds give (generally) different orders: the round number
    is part of the hash (paper §3.3)."""
    if len(candidates) < 10:
        return
    orders = {tuple(sample_order(candidates, k + i)) for i in range(6)}
    assert len(orders) > 1


@settings(max_examples=200)
@given(ids, st.integers(0, 1000), st.integers(1, 10),
       st.data())
def test_mostly_consistent_under_divergence(candidates, k, missing, data):
    """If node B's view misses `missing` entries of node A's view, their
    samples differ by at most `missing` elements (mostly-consistent)."""
    s = data.draw(st.integers(1, max(1, len(candidates))))
    b_view = candidates[:-missing] if missing < len(candidates) else candidates[:1]
    sa = set(select_sample(candidates, k, s))
    sb = set(select_sample(b_view, k, s))
    assert len(sa - sb) <= missing


@given(ids, st.integers(0, 1000), st.integers(1, 5))
def test_aggregators_prefix_of_sample(candidates, k, a):
    """§3.6: aggregators are the first `a` of the same hashed order, so
    A^k ⊆ S^k whenever s ≥ a."""
    s = min(len(candidates), a + 3)
    if s < a:
        return
    sample = select_sample(candidates, k, s)
    aggs = select_aggregators(candidates, k, a)
    assert aggs == sample[:a]


# ---------------------------------------------------------------------------
# Live Sampler state machine (ping/pong over the simulated fabric)
# ---------------------------------------------------------------------------


class _Peer:
    """Answers every Ping with a Pong, like a live MoDeST node."""

    def __init__(self, nid, sim, net):
        self.node_id, self.sim, self.net = nid, sim, net
        self.online = True
        net.register(self)

    def receive(self, msg):
        if isinstance(msg, M.Ping):
            self.net.send(self.node_id, msg.sender,
                          M.Pong(sender=self.node_id, round_k=msg.round_k))


class _Host(_Peer):
    """A node that runs the Sampler over a fixed candidate view."""

    def __init__(self, nid, sim, net, all_ids, timeout=1.0):
        super().__init__(nid, sim, net)
        self.timeout = timeout
        self.all_ids = list(all_ids)
        self.sampler = Sampler(self)

    def candidates(self, round_k):
        return self.all_ids

    def receive(self, msg):
        if isinstance(msg, M.Pong):
            self.sampler.on_pong(msg.round_k, msg.sender)
        else:
            super().receive(msg)


def _harness(n=8):
    sim = Simulator()
    net = Network(sim, n, latency=np.full((n, n), 0.01), contention=False)
    ids = [str(i) for i in range(n)]
    host = _Host("0", sim, net, ids)
    peers = [_Peer(i, sim, net) for i in ids[1:]]
    return sim, host, ids, peers


def test_sampler_resolves_with_s_live_nodes():
    sim, host, ids, _ = _harness()
    out = []
    host.sampler.sample(3, 4, out.append)
    sim.run(until=30.0)
    assert len(out) == 1 and len(out[0]) == 4
    assert set(out[0]) <= set(ids)


def test_concurrent_same_round_samples_both_resolve():
    """Regression: a node that is trainer for round k and aggregator for
    round k+1 issues two sample(k+1, …) calls. The second must not clobber
    the first — both continuations fire, each with its own size."""
    sim, host, ids, _ = _harness()
    done = {}
    host.sampler.sample(5, 4, lambda L: done.setdefault("trainer->aggs", L))
    host.sampler.sample(5, 2, lambda L: done.setdefault("agg->sample", L))
    sim.run(until=30.0)
    assert set(done) == {"trainer->aggs", "agg->sample"}
    assert len(done["trainer->aggs"]) == 4
    assert len(done["agg->sample"]) == 2
    for L in done.values():
        assert len(set(L)) == len(L) and set(L) <= set(ids)


def test_concurrent_samples_tracked_independently():
    """No pong misattribution: each pending sample keeps its own state."""
    sim, host, ids, _ = _harness()
    host.sampler.sample(7, 3, lambda L: None)
    host.sampler.sample(7, 3, lambda L: None)
    assert len(host.sampler._pending) == 2
    tokens = list(host.sampler._pending)
    assert tokens[0] != tokens[1]
    sim.run(until=30.0)
    assert not host.sampler._pending      # all state cleaned up
    assert not host.sampler._by_round


def test_resolved_sample_cancels_stale_timers():
    sim, host, ids, _ = _harness()
    host.sampler.sample(2, 3, lambda L: None)
    sim.run(until=30.0)
    # every deadline/advance/retry the sample scheduled is cancelled or
    # spent: nothing owned by the sampler is left ticking
    assert not host.sampler._pending
    assert sim.pending == 0


def test_dead_population_resolves_best_effort():
    """All candidates offline: after MAX_RETRIES the continuation still
    fires (best effort) instead of leaking a pending sample forever."""
    sim, host, ids, peers = _harness()
    for p in peers:
        p.online = False
    out = []
    host.sampler.sample(4, 4, out.append)
    sim.run(until=200.0)
    assert len(out) == 1
    assert set(out[0]) <= {"0"}           # only the loopback self-pong
    assert not host.sampler._pending

