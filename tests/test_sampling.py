"""Alg. 1 properties: determinism, cross-node consistency, and the
*mostly-consistent* guarantee under view divergence."""

import string

from hypothesis import given, settings, strategies as st

from repro.core.hashing import sample_order, select_aggregators, select_sample

ids = st.lists(st.text(string.ascii_lowercase + string.digits, min_size=1,
                       max_size=12), min_size=1, max_size=60, unique=True)


@given(ids, st.integers(0, 10_000))
def test_order_deterministic(candidates, k):
    assert sample_order(candidates, k) == sample_order(list(candidates), k)


@given(ids, st.integers(0, 10_000))
def test_order_is_permutation(candidates, k):
    assert sorted(sample_order(candidates, k)) == sorted(candidates)


@given(ids, st.integers(0, 1000), st.integers(1, 20))
def test_consistent_views_consistent_samples(candidates, k, s):
    """Two nodes with identical views derive identical samples — the FL
    server's single sample, decentralized."""
    a = select_sample(candidates, k, s)
    b = select_sample(sorted(candidates), k, s)   # different iteration order
    assert a == b


@given(ids, st.integers(0, 1000))
def test_rounds_randomize_order(candidates, k):
    """Different rounds give (generally) different orders: the round number
    is part of the hash (paper §3.3)."""
    if len(candidates) < 10:
        return
    orders = {tuple(sample_order(candidates, k + i)) for i in range(6)}
    assert len(orders) > 1


@settings(max_examples=200)
@given(ids, st.integers(0, 1000), st.integers(1, 10),
       st.data())
def test_mostly_consistent_under_divergence(candidates, k, missing, data):
    """If node B's view misses `missing` entries of node A's view, their
    samples differ by at most `missing` elements (mostly-consistent)."""
    s = data.draw(st.integers(1, max(1, len(candidates))))
    b_view = candidates[:-missing] if missing < len(candidates) else candidates[:1]
    sa = set(select_sample(candidates, k, s))
    sb = set(select_sample(b_view, k, s))
    assert len(sa - sb) <= missing


@given(ids, st.integers(0, 1000), st.integers(1, 5))
def test_aggregators_prefix_of_sample(candidates, k, a):
    """§3.6: aggregators are the first `a` of the same hashed order, so
    A^k ⊆ S^k whenever s ≥ a."""
    s = min(len(candidates), a + 3)
    if s < a:
        return
    sample = select_sample(candidates, k, s)
    aggs = select_aggregators(candidates, k, a)
    assert aggs == sample[:a]
