"""Alg. 2 registry is a last-writer-wins CRDT: merge must be commutative,
associative, idempotent, and converge regardless of delivery order."""

from hypothesis import given, strategies as st

from repro.core.registry import JOINED, LEFT, Registry

event = st.tuples(st.sampled_from(["a", "b", "c", "d", "e"]),
                  st.integers(1, 20),
                  st.sampled_from([JOINED, LEFT]))
events = st.lists(event, max_size=40)


def reg_from(evs) -> Registry:
    r = Registry()
    for j, c, e in evs:
        r.update(j, c, e)
    return r


def as_dict(r: Registry):
    return (dict(r.events), dict(r.counters))


@given(events, events)
def test_merge_commutative(e1, e2):
    a, b = reg_from(e1), reg_from(e2)
    ab = reg_from(e1)
    ab.merge(b)
    ba = reg_from(e2)
    ba.merge(a)
    assert as_dict(ab) == as_dict(ba)


@given(events, events, events)
def test_merge_associative(e1, e2, e3):
    def merged(order):
        r = Registry()
        for evs in order:
            r.merge(reg_from(evs))
        return as_dict(r)

    assert merged([e1, e2, e3]) == merged([e3, e1, e2])


@given(events)
def test_merge_idempotent(e1):
    a = reg_from(e1)
    before = as_dict(a)
    a.merge(reg_from(e1))
    assert as_dict(a) == before


@given(events)
def test_highest_counter_wins(e1):
    r = reg_from(e1)
    for j in r.counters:
        best = max(c for (jj, c, _e) in e1 if jj == j)
        assert r.counters[j] == best
        # the stored event is one of the max-counter events for j
        assert r.events[j] in {e for (jj, c, e) in e1
                               if jj == j and c == best}


@given(events)
def test_registered_iff_latest_joined(e1):
    r = reg_from(e1)
    for j in r.registered():
        assert r.events[j] == JOINED


def test_update_rejects_stale():
    r = Registry()
    assert r.update("x", 5, JOINED)
    assert not r.update("x", 3, LEFT)       # older counter: rejected
    assert r.events["x"] == JOINED
    assert r.update("x", 5, LEFT)           # tie: breaks toward 'left'
    assert not r.update("x", 5, JOINED)     # ...and never back
    assert not r.is_registered("x")
    assert r.update("x", 6, JOINED)
    assert r.is_registered("x")
