"""End-to-end protocol behaviour in the discrete-event simulator:
progress, load balance, churn (Fig. 5), crash resilience (Fig. 6),
stale-message safety."""

import numpy as np
import pytest

from repro.config import ModestConfig, TrainConfig
from repro.core import messages as M
from repro.core.node import ModestNode
from repro.core.tasks import AbstractTask
from repro.sim.clock import Simulator
from repro.sim.network import Network
from repro.sim.runner import DSGDSession, ModestSession, fedavg_session

MCFG = ModestConfig(n_nodes=30, sample_size=5, n_aggregators=2,
                    success_fraction=1.0, ping_timeout=1.0, activity_window=20)
TCFG = TrainConfig()


def small_session(**kw):
    defaults = dict(n_nodes=30, mcfg=MCFG, tcfg=TCFG,
                    task=AbstractTask(model_bytes_=100_000), seed=0)
    defaults.update(kw)
    return ModestSession(**defaults)


def test_rounds_progress():
    res = small_session().run(120.0)
    assert res.rounds_completed > 30


def test_all_nodes_participate():
    s = small_session()
    res = s.run(300.0)
    assert res.usage["min_node_bytes"] > 0          # nobody starves
    # MoDeST spreads load far better than FL: max << total
    assert res.usage["max_node_bytes"] < 0.25 * res.usage["total_bytes"]


def test_overhead_is_marginal():
    """Table 4 bottom: view/ping overhead is a few percent for >=100KB models."""
    res = small_session().run(200.0)
    assert 0.0 < res.overhead_fraction < 0.15


def test_fedavg_concentrates_load():
    res = fedavg_session(n_nodes=30, mcfg=MCFG, tcfg=TCFG,
                         task=AbstractTask(model_bytes_=100_000), seed=0).run(120.0)
    # the single fixed aggregator carries ~half of all traffic (§4.4)
    assert res.usage["max_node_bytes"] > 0.35 * res.usage["total_bytes"]


def test_dsgd_balances_but_costs_more():
    task = AbstractTask(model_bytes_=100_000)
    rd = DSGDSession(n_nodes=30, tcfg=TCFG, task=task, seed=0).run(120.0)
    rm = small_session().run(120.0)
    # perfectly balanced
    assert rd.usage["max_node_bytes"] < 1.1 * max(rd.usage["min_node_bytes"], 1)
    # paper: 3–14x total communication vs MoDeST (per unit time here)
    assert rd.usage["total_bytes"] > 1.5 * rm.usage["total_bytes"]


def test_crash_resilience_80pct():
    """Fig. 6: crash 80% of nodes; the session must keep completing rounds."""
    s = small_session()
    rng = np.random.default_rng(0)
    victims = rng.choice(30, size=24, replace=False)
    for i, v in enumerate(victims):
        s.schedule_crash(30.0 + i * 2.0, str(v))
    res = s.run(400.0)
    rounds_at_crash_end = max(
        (k for t, k in res.round_times if t < 100.0), default=0)
    assert res.rounds_completed > rounds_at_crash_end + 10, \
        "no progress after crashes settled"


def test_join_propagates():
    """Fig. 5: a joiner becomes a candidate at every node within ~n/s rounds."""
    s = small_session()
    s.schedule_join(20.0, "99", data_idx=0)
    s.run(400.0)
    know = sum(1 for node in s.nodes.values()
               if node.node_id != "99" and node.registry.is_registered("99"))
    assert know >= 0.9 * 30
    joiner = s.nodes["99"]
    assert joiner.k_train > 0 or joiner.k_agg > 0   # eventually sampled


def test_graceful_leave_removes_candidate():
    s = small_session()
    s.schedule_leave(30.0, "7")
    s.run(200.0)
    others_knowing = sum(
        1 for n in s.nodes.values()
        if n.node_id != "7" and not n.registry.is_registered("7"))
    assert others_knowing >= 15    # spread via views


def test_stale_messages_ignored():
    sim = Simulator()
    net = Network(sim, 3)
    task = AbstractTask(model_bytes_=1000)
    n = ModestNode("0", sim, net, MCFG, TCFG, task)
    n.bootstrap(["0", "1", "2"])
    n.k_train = 10
    n.receive(M.TrainMsg(sender="1", round_k=3,
                         model=M.ModelPayload(nbytes=1000), view=None))
    assert n._train_round_pending is None           # stale k<k_train dropped
    n.k_agg = 10
    n.receive(M.AggregateMsg(sender="1", round_k=4,
                             model=M.ModelPayload(nbytes=1000), view=None))
    assert n._theta_list == []


def test_higher_round_cancels_training():
    sim = Simulator()
    net = Network(sim, 3)
    task = AbstractTask(model_bytes_=1000, batches_per_client=100)  # slow
    n = ModestNode("0", sim, net, MCFG, TCFG, task)
    n.bootstrap(["0", "1", "2"])
    n.receive(M.TrainMsg(sender="1", round_k=1,
                         model=M.ModelPayload(nbytes=1000), view=None))
    assert n._train_round_pending == 1
    n.receive(M.TrainMsg(sender="2", round_k=5,
                         model=M.ModelPayload(nbytes=1000), view=None))
    assert n.k_train == 5
    assert n._train_round_pending == 5              # old run cancelled, new started


def test_gossip_baseline_runs():
    """Gossip Learning (paper §5 comparison): fixed-period cycles, no
    rounds/aggregators; perfectly balanced like D-SGD."""
    from repro.sim.runner import GossipSession
    res = GossipSession(n_nodes=20, tcfg=TCFG,
                        task=AbstractTask(model_bytes_=100_000),
                        seed=0).run(120.0)
    assert res.rounds_completed > 5
    u = res.usage
    assert u["min_node_bytes"] > 0
    assert u["max_node_bytes"] < 3 * u["min_node_bytes"]


def test_gossip_never_pushes_to_self():
    """Regression: rng.integers(0, n) could draw the sender itself — a
    no-op transfer that still inflated Table-4 byte accounting."""
    from repro.sim.runner import GossipSession
    s = GossipSession(n_nodes=5, tcfg=TCFG,
                      task=AbstractTask(model_bytes_=100_000),
                      period=1.0, seed=3)
    pushes = []
    orig_send = s.net.send

    def spy(src, dst, msg):
        if isinstance(msg, M.AggregateMsg):
            pushes.append((src, dst))
        return orig_send(src, dst, msg)

    s.net.send = spy
    s.run(60.0)
    assert len(pushes) > 100          # n=5, 1 s period, 60 s — plenty drawn
    assert all(src != dst for src, dst in pushes)
