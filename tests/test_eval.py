"""`repro.eval` — metric math on synthetic results + one real matrix."""

import math

import pytest

from repro.eval import (EvalMetrics, compare, evaluate_session,
                        scenario_matrix, time_to_metric, time_to_round)
from repro.sim.runner import SessionResult


def _result(**kw):
    r = SessionResult()
    for k, v in kw.items():
        setattr(r, k, v)
    return r


def test_time_to_metric_first_crossing():
    r = _result(history=[{"t": 10.0, "accuracy": 0.2},
                         {"t": 30.0, "accuracy": 0.55},
                         {"t": 20.0, "accuracy": 0.5},
                         {"t": 40.0, "accuracy": 0.4}])   # non-monotone ok
    assert time_to_metric(r, 0.5) == 20.0                 # sorted by t
    assert time_to_metric(r, 0.9) is None
    assert time_to_metric(r, 0.45, key="accuracy",
                          higher_is_better=False) == 10.0


def test_time_to_round_proxy():
    r = _result(round_times=[(5.0, 1), (9.0, 3), (12.0, 4)])
    assert time_to_round(r, 2) == 9.0                     # first k >= 2
    assert time_to_round(r, 9) is None


def test_evaluate_session_collects_three_axes():
    r = _result(round_times=[(5.0, 1), (8.0, 2)],
                usage={"total_bytes": 100, "sent_bytes": 60},
                train_node_seconds=12.5, trainings_completed=3,
                rounds_completed=2)
    m = evaluate_session(r, algo="modest", target_round=2)
    assert m.time_to_target_s == 8.0
    assert m.communication_bytes == 60
    assert m.train_node_seconds == 12.5


def test_compare_ratios_and_wedged_baseline():
    base = EvalMetrics("modest", 10.0, 1000, 50.0)
    slow = EvalMetrics("dsgd", 30.0, 15000, 500.0)
    dead = EvalMetrics("gossip", None, 400, 25.0)
    out = compare({"modest": base, "dsgd": slow, "gossip": dead})
    assert out["dsgd"] == {"time_to_target_x": 3.0,
                           "communication_x": 15.0,
                           "train_resources_x": 10.0}
    assert out["gossip"]["time_to_target_x"] == math.inf  # never reached
    with pytest.raises(KeyError):
        compare({"dsgd": slow})


def test_scenario_matrix_single_invocation_covers_algos_and_regimes():
    out = scenario_matrix(algos=("modest", "dsgd", "fedavg"),
                          regimes=("homogeneous", "diurnal"),
                          n=16, seeds=(0,), duration=60.0, target_round=3)
    algos = {row["algo"] for row in out["summary"]}
    regimes = {row["regime"] for row in out["summary"]}
    assert algos == {"modest", "dsgd", "fedavg"}
    assert regimes == {"homogeneous", "diurnal"}
    assert len(out["rows"]) == 6
    for row in out["rows"]:
        assert row["communication_gb"] > 0
        assert row["train_node_hours"] >= 0
    # ratios exist vs the modest baseline for every regime
    assert set(out["ratios"]) == {"homogeneous", "diurnal"}
    for regime in out["ratios"].values():
        assert set(regime) == {"dsgd", "fedavg"}
        for axes in regime.values():
            assert set(axes) == {"time_to_target_x", "communication_x",
                                 "train_resources_x"}


def test_unknown_algo_and_regime_raise():
    from repro.eval import Scenario, run_scenario
    with pytest.raises(ValueError):
        run_scenario(Scenario(algo="sgd??", regime="diurnal"))
    with pytest.raises(ValueError):
        Scenario(algo="modest", regime="lunar").profile()


def test_scenario_matrix_fault_axis():
    """Fault regimes compose with trace regimes as a matrix axis: rows
    are tagged, schedules actually inject, and ratio keys distinguish
    the faulty cells."""
    out = scenario_matrix(algos=("modest", "gossip"),
                          regimes=("homogeneous",),
                          faults=(None, "lossy_wan"),
                          n=16, seeds=(0,), duration=60.0, target_round=3)
    assert len(out["rows"]) == 4
    by_fault = {row["fault"] for row in out["rows"]}
    assert by_fault == {"clean", "lossy_wan"}
    for row in out["rows"]:
        if row["fault"] == "lossy_wan":
            assert row["fault_injections"] > 0
        else:
            assert row["fault_injections"] == 0
    assert set(out["ratios"]) == {"homogeneous", "homogeneous+lossy_wan"}


def test_unknown_fault_regime_raises():
    from repro.eval import Scenario
    with pytest.raises(ValueError):
        Scenario(algo="modest", regime="diurnal",
                 fault="gremlins").fault_schedule()
